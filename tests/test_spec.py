"""Speculative decoding (repro/spec/): draft-verify exactness, rollback
under shared pages, traced-once verify, drafter determinism.

The headline guarantee is the repo's exactness discipline applied to
speculation: greedy engine output with spec ON is bitwise identical to
spec OFF (and to a solo ``serve_batch`` decode) across GQA, MLA and int8
paged KV, in both cache modes, with either drafter — acceptance only ever
changes how many dispatches the stream costs, never its tokens.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import serve_batch
from repro.configs import get_config, reduced
from repro.models import init_params
from repro.prefix import PrefixTree
from repro.serving import (
    EngineConfig,
    EnginePolicies,
    PrefixAwareAdmission,
    Request,
    ServingEngine,
)
from repro.spec import NgramDrafter, SpecConfig


def _setup(arch, **cfg_kw):
    cfg = reduced(get_config(arch)).with_(remat=False, **cfg_kw)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, params, policies=None, **ecfg_kw):
    kw = dict(n_slots=2, cache_len=48, cache_mode="paged", page_size=8,
              prefill_chunk=8)
    kw.update(ecfg_kw)
    return ServingEngine(cfg, params, EngineConfig(**kw), policies=policies)


def _solo(cfg, params, prompt, gen, cache_len=48):
    out, _ = serve_batch(cfg, params,
                         {"tokens": jnp.asarray([prompt], jnp.int32)},
                         cache_len=cache_len, gen_tokens=gen)
    return np.asarray(out)[0].tolist()


def _mixed_workload(cfg, rng, n=3):
    """Repetitive prompts (draftable; high acceptance) mixed with random
    ones (low acceptance) — exercises accept lengths from 0 to k."""
    arrivals = []
    for i in range(n):
        if i % 2 == 0:
            pat = rng.integers(0, cfg.vocab_size, 4).tolist()
            prompt = (pat * 4)[: 12 + i]
        else:
            prompt = rng.integers(0, cfg.vocab_size, 12 + i).tolist()
        arrivals.append((2 * i, prompt, 8 + i))
    return arrivals


class ScriptedDrafter:
    """Test-only drafter: maps each lane's history to a scripted draft.
    Swapped in via ``engine._drafter`` to pin the verify window's accept
    and reject paths deterministically (the ngram drafter's proposals
    depend on whether the model's output happens to repeat)."""

    def __init__(self, fn, k):
        self.fn, self.k = fn, k

    def admit(self, slot, history):
        pass

    def release(self, slot):
        pass

    def propose(self, slots, histories):
        return [list(self.fn(h))[: self.k] for h in histories]


def _oracle_fn(refs):
    """refs: {tuple(prompt): solo_output_tokens}.  Returns the TRUE greedy
    continuation of a history (acceptance-1.0 oracle)."""
    def fn(hist):
        for p, ref in refs.items():
            if tuple(hist[: len(p)]) == p:
                emitted = len(hist) - len(p)
                return ref[emitted:]
        raise AssertionError("history matches no known prompt")
    return fn


def _adversarial_fn(refs, vocab):
    """Every drafted token is (true token + 1) mod vocab: guaranteed
    rejection, so every dispatch exercises rollback."""
    oracle = _oracle_fn(refs)
    return lambda hist: [(t + 1) % vocab for t in oracle(hist)]


# ---------------------------------------------------------------------------
# Bitwise exactness (the acceptance bar)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,kv,expect_drafts", [
    ("llama3.2-1b", "bf16", True),    # GQA pages
    ("minicpm3-4b", "bf16", False),   # MLA latent pages (output non-repetitive)
    ("llama3.2-1b", "int8", True),    # byte-size pages + scales
])
def test_spec_is_bitwise_invisible_paged(arch, kv, expect_drafts):
    """Greedy tokens with speculation ON equal OFF equal each request's
    solo decode; where the workload is draftable the run must actually
    speculate (non-vacuous)."""
    cfg, params = _setup(arch, kv_cache_dtype=kv)
    rng = np.random.default_rng(0)
    arrivals = _mixed_workload(cfg, rng)
    outs = {}
    for spec in (None, SpecConfig(enabled=True, k=4)):
        engine = _engine(cfg, params, spec=spec)
        m = engine.run(arrivals)
        outs[spec is not None] = {r.req_id: r.output_tokens for r in m.finished}
        if spec is not None:
            assert m.verify_dispatches > 0, "speculation never engaged"
            if expect_drafts:
                assert m.spec_proposed > 0
            engine.store.manager.check_invariants()
            assert engine.store.manager.pages_in_use == 0
    assert outs[True] == outs[False]
    for i, (_, p, g) in enumerate(arrivals):
        assert outs[True][i] == _solo(cfg, params, p, g), (
            f"{arch}/{kv}: request {i} diverged from its solo decode")


@pytest.mark.parametrize("arch,kv", [
    ("minicpm3-4b", "bf16"),      # MLA verify window
    ("llama3.2-1b", "int8"),      # int8 page writes in the verify window
])
def test_spec_accept_and_reject_paths_exact(arch, kv):
    """Deterministic coverage of both verify outcomes: an oracle drafter
    (every draft correct -> full windows accepted) and an adversarial one
    (every draft wrong -> every dispatch rolls back) both reproduce the
    solo stream bitwise."""
    cfg, params = _setup(arch, kv_cache_dtype=kv)
    rng = np.random.default_rng(10)
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in (12, 14)]
    gens = [9, 8]
    refs = {tuple(p): _solo(cfg, params, p, g)
            for p, g in zip(prompts, gens)}
    spec = SpecConfig(enabled=True, k=3)
    for mode in ("oracle", "adversarial"):
        engine = _engine(cfg, params, spec=spec)
        fn = (_oracle_fn(refs) if mode == "oracle"
              else _adversarial_fn(refs, cfg.vocab_size))
        engine._drafter = ScriptedDrafter(fn, spec.k)
        m = engine.run([(0, prompts[0], gens[0]), (1, prompts[1], gens[1])])
        outs = {r.req_id: r.output_tokens for r in m.finished}
        for i, p in enumerate(prompts):
            assert outs[i] == refs[tuple(p)], f"{arch}/{kv}/{mode}: req {i}"
        assert m.spec_proposed > 0
        if mode == "oracle":
            assert m.spec_accepted == m.spec_proposed
        else:
            assert m.spec_accepted == 0       # every window rolled back
        engine.store.manager.check_invariants()
        assert engine.store.manager.pages_in_use == 0


def test_spec_is_bitwise_invisible_slot_mode():
    cfg, params = _setup("llama3.2-1b")
    rng = np.random.default_rng(1)
    arrivals = _mixed_workload(cfg, rng)
    outs = {}
    for spec in (None, SpecConfig(enabled=True, k=4)):
        engine = _engine(cfg, params, spec=spec, cache_mode="slot",
                         page_size=16, prefill_chunk=None)
        m = engine.run(arrivals)
        outs[spec is not None] = {r.req_id: r.output_tokens for r in m.finished}
    assert outs[True] == outs[False]
    for i, (_, p, g) in enumerate(arrivals):
        assert outs[True][i] == _solo(cfg, params, p, g), i


def test_spec_draft_model_drafter_exact():
    """The draft-model drafter proposes from its own small transformer +
    slot cache; target-side outputs stay bitwise exact regardless of what
    it proposes."""
    cfg, params = _setup("llama3.2-1b")
    rng = np.random.default_rng(2)
    arrivals = _mixed_workload(cfg, rng)
    spec = SpecConfig(enabled=True, k=3, drafter="model", draft_layers=2)
    engine = _engine(cfg, params, spec=spec)
    m = engine.run(arrivals)
    assert m.verify_dispatches > 0 and m.spec_proposed > 0
    outs = {r.req_id: r.output_tokens for r in m.finished}
    for i, (_, p, g) in enumerate(arrivals):
        assert outs[i] == _solo(cfg, params, p, g), i
    # lane ledgers are released with their lanes
    assert engine._drafter._fed == {}


def test_spec_respects_eos_and_budget():
    """EOS inside an accepted window truncates the stream exactly where
    plain decode would; a 1-token budget still admits (k clamps to 0)."""
    cfg, params = _setup("llama3.2-1b")
    rng = np.random.default_rng(3)
    pat = rng.integers(0, cfg.vocab_size, 4).tolist()
    prompt = (pat * 4)[:13]
    ref = _solo(cfg, params, prompt, 12)
    eos = ref[5]
    for spec in (None, SpecConfig(enabled=True, k=4)):
        engine = _engine(cfg, params, spec=spec, eos_token=eos)
        m = engine.run([(0, prompt, 12), (0, prompt, 1)])
        outs = {r.req_id: r.output_tokens for r in m.finished}
        assert outs[0] == ref[: ref.index(eos) + 1]
        assert outs[1] == ref[:1]


# ---------------------------------------------------------------------------
# Rollback under CoW-shared pages (spec + prefix cache)
# ---------------------------------------------------------------------------

def test_spec_rollback_under_cow_shared_pages():
    """Rejected drafts roll back lanes whose verify window overlapped
    pages the prefix tree shares: the window is CoW-forked before the
    dispatch, so truncation never corrupts the shared trunk.  An
    adversarial drafter (every token wrong) forces rollback on every
    dispatch."""
    cfg, params = _setup("minicpm3-4b")
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, 16).tolist()   # exactly 2 pages
    ref = _solo(cfg, params, prompt, 10)
    spec = SpecConfig(enabled=True, k=4)
    engine = _engine(cfg, params, spec=spec, prefix_cache=True)
    engine._drafter = ScriptedDrafter(
        _adversarial_fn({tuple(prompt): ref}, cfg.vocab_size), spec.k)
    m = engine.run([(0, prompt, 10), (2, prompt, 10)])      # 2nd = full hit
    assert m.prefix_hits == 1 and m.prefix_cow_forks >= 1
    assert m.spec_proposed > 0 and m.spec_accepted == 0, (
        "adversarial drafts must all be rejected and rolled back")
    engine.store.manager.check_invariants()
    for r in m.finished:
        assert r.output_tokens == ref
    # only the tree's published prompt pages remain held
    assert engine.store.manager.pages_in_use == m.prefix_tree_pages


def test_spec_overshoot_reserved_in_admission():
    """The verify window writes up to k rows past the accepted position;
    admission must reserve them or a full pool would overcommit."""
    cfg, params = _setup("llama3.2-1b")
    spec = SpecConfig(enabled=True, k=4)
    engine = _engine(cfg, params, spec=spec)
    with pytest.raises(ValueError, match="cache positions"):
        # 36 + 10 + 4 overshoot > 48 + 1; fits without the overshoot
        engine.add_request(list(range(100, 136)), 10)
    engine.add_request(list(range(100, 136)), 5)            # fits with it


# ---------------------------------------------------------------------------
# Traced-once verify
# ---------------------------------------------------------------------------

def test_spec_verify_traced_once_across_acceptance_lengths():
    """Acceptance length is data, not shape: a run whose windows accept
    0..k drafts compiles the verify dispatch exactly once."""
    cfg, params = _setup("llama3.2-1b")
    spec = SpecConfig(enabled=True, k=5)    # width 6: not shared with other tests
    engine = _engine(cfg, params, spec=spec)
    rng = np.random.default_rng(5)
    m = engine.run(_mixed_workload(cfg, rng))
    assert m.verify_dispatches >= 3
    rates = {int(a) for a in range(spec.k + 1)}
    assert engine._verify_fn._cache_size() == 1, (
        f"verify retraced: {engine._verify_fn._cache_size()} entries "
        f"(acceptance lengths seen should all share one trace: {rates})")


# ---------------------------------------------------------------------------
# N-gram drafter determinism
# ---------------------------------------------------------------------------

def test_ngram_drafter_self_lookup():
    d = NgramDrafter(SpecConfig(enabled=True, k=3, ngram_max=3))
    # trailing [1,2,3] occurred earlier at position 0 -> continuation 4,5,6
    hist = [1, 2, 3, 4, 5, 6, 1, 2, 3]
    assert d.propose([0], [hist]) == [[4, 5, 6]]
    # most recent earlier occurrence wins
    hist = [1, 2, 9, 1, 2, 7, 1, 2]
    assert d.propose([0], [hist]) == [[7, 1, 2]]
    # shorter-n fallback: only the trailing 1-gram [7] recurs -> 8,9,7
    assert d.propose([0], [[7, 8, 9, 7]]) == [[8, 9, 7]]
    # no earlier occurrence of any trailing n-gram -> empty draft
    assert d.propose([0], [[3, 1, 4, 1, 5, 9, 2, 6]]) == [[]]
    assert d.propose([0], [[1, 2, 3, 4]]) == [[]]


def test_ngram_drafter_prefers_longest_ngram():
    d = NgramDrafter(SpecConfig(enabled=True, k=2, ngram_max=3))
    # the 3-gram [5,6,7] matches at position 1 (-> 8,9); the 1-gram [7]
    # also occurs later at position 7 (-> 0,5) — the longer match wins
    hist = [9, 5, 6, 7, 8, 9, 4, 7, 0, 5, 6, 7]
    assert d.propose([0], [hist]) == [[8, 9]]


def test_ngram_drafter_tree_fallback_deterministic():
    """Misses in the lane's own history fall back to the radix tree's
    token paths, visited in sorted order (dict-order independent)."""
    tree = PrefixTree(4)
    tree.insert([7, 8, 1, 2, 3, 4, 5, 6], [1, 2])
    tree.insert([7, 8, 9, 9, 1, 2, 3, 4], [1, 3])  # shares page [7,8,1,2]? no: splits
    d = NgramDrafter(SpecConfig(enabled=True, k=2, ngram_max=2), tree=tree)
    hist = [50, 51, 2, 3]            # trailing [2,3] appears in both paths
    (draft,) = d.propose([0], [hist])
    assert draft == [4, 5]           # sorted-smallest path [7,8,1,...] wins
    # identical call -> identical draft (stateless + deterministic)
    assert d.propose([0], [hist]) == [[4, 5]]


# ---------------------------------------------------------------------------
# Config / gating
# ---------------------------------------------------------------------------

def test_engine_rejects_spec_on_nonchunkable_stacks():
    moe_cfg, moe_params = _setup("granite-moe-3b-a800m")
    with pytest.raises(ValueError, match="row-independent"):
        ServingEngine(moe_cfg, moe_params, EngineConfig(
            spec=SpecConfig(enabled=True, k=4)))


def test_spec_mixed_sampling_falls_back_to_plain_decode():
    """A stochastic lane in the batch disables speculation for that step
    (the fused accept rule is exact for argmax only) — outputs must still
    match the spec-off engine."""
    from repro.serving import SamplingParams

    cfg, params = _setup("llama3.2-1b")
    rng = np.random.default_rng(6)
    p1 = rng.integers(0, cfg.vocab_size, 12).tolist()
    p2 = rng.integers(0, cfg.vocab_size, 12).tolist()
    sto = SamplingParams(greedy=False, temperature=0.8, top_k=4, seed=7)
    outs = {}
    for spec in (None, SpecConfig(enabled=True, k=4)):
        engine = _engine(cfg, params, spec=spec)
        # the stochastic lane arrives first and outlives the greedy one, so
        # the running batch is mixed for the greedy lane's entire life
        m = engine.run([(0, p2, 14, sto), (0, p1, 6)])
        outs[spec is not None] = {r.req_id: r.output_tokens for r in m.finished}
        if spec is not None:
            assert m.verify_dispatches == 0, "speculated with a stochastic lane"
    assert outs[True] == outs[False]


def test_spec_config_roundtrip_through_runtime():
    from repro.api import RuntimeConfig

    rt = RuntimeConfig(spec=SpecConfig(enabled=True, k=3, drafter="model",
                                       draft_layers=3))
    rt2 = RuntimeConfig.from_dict(rt.to_dict())
    assert rt2.spec == rt.spec
    assert rt2 == rt
    with pytest.raises(ValueError, match="drafter"):
        SpecConfig(drafter="medusa")
    with pytest.raises(ValueError, match="k must"):
        SpecConfig(k=0)


# ---------------------------------------------------------------------------
# Prefix-aware admission (satellite: ordering only, outputs invariant)
# ---------------------------------------------------------------------------

def test_prefix_aware_admission_groups_hot_prefix():
    pol = PrefixAwareAdmission(patience=2)
    sigs = {0: ("a",), 1: ("b",), 2: ("a",), 3: None}
    pol.bind(lambda r: sigs[r.req_id])
    reqs = [Request(req_id=i, prompt=[1], max_new_tokens=1) for i in range(4)]
    ok = lambda r: True
    bucket = lambda r: 1
    # unprimed: FIFO head, which primes the signature to ("a",)
    assert pol.next_group(reqs, 1, ok, bucket) == [0]
    # now the matching later arrival jumps the queue
    assert pol.next_group(reqs[1:], 1, ok, bucket) == [1]   # req 2 at index 1
    # no match left -> FIFO head
    assert pol.next_group([reqs[1], reqs[3]], 1, ok, bucket) == [0]


def test_prefix_aware_admission_patience_bounds_starvation():
    pol = PrefixAwareAdmission(patience=2)
    pol.bind(lambda r: ("hot",) if r.req_id >= 100 else None)
    head = Request(req_id=0, prompt=[1], max_new_tokens=1)
    ok = lambda r: True
    bucket = lambda r: 1
    # prime the hot signature
    assert pol.next_group([Request(req_id=100, prompt=[1], max_new_tokens=1)],
                          1, ok, bucket) == [0]
    picked = []
    for i in range(4):
        hot = Request(req_id=101 + i, prompt=[1], max_new_tokens=1)
        idx, = pol.next_group([head, hot], 1, ok, bucket)
        picked.append([head, hot][idx].req_id)
    # two skip-aheads, then patience forces the starved FIFO head through
    assert picked[:2] == [101, 102] and picked[2] == 0


def test_prefix_aware_admission_through_engine_is_exact():
    """End-to-end: ordering changes, outputs don't — every request still
    matches its solo decode, and shared-prefix requests actually hit."""
    cfg, params = _setup("llama3.2-1b")
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab_size, 16).tolist()
    prompts = [shared + rng.integers(0, cfg.vocab_size, n).tolist()
               for n in (5, 9, 3)] + [rng.integers(0, cfg.vocab_size, 11).tolist()]
    gens = [6, 5, 4, 5]
    engine = _engine(cfg, params, prefix_cache=True,
                     policies=EnginePolicies(admission=PrefixAwareAdmission()))
    m = engine.run([(0, p, g) for p, g in zip(prompts, gens)])
    assert m.prefix_hits >= 2
    outs = {r.req_id: r.output_tokens for r in m.finished}
    for i, (p, g) in enumerate(zip(prompts, gens)):
        assert outs[i] == _solo(cfg, params, p, g), i


# ---------------------------------------------------------------------------
# Satellite: int8 full-prompt prefix hits (one-page cap lifted)
# ---------------------------------------------------------------------------

def test_int8_full_prompt_prefix_hit_is_exact():
    """int8 pools now CoW-fork the boundary page on a FULL-prompt hit and
    resume at the final token (every admission is forced through the
    dequant-consistent chunk step), instead of dropping the last page."""
    cfg, params = _setup("llama3.2-1b", kv_cache_dtype="int8")
    rng = np.random.default_rng(8)
    prompt = rng.integers(0, cfg.vocab_size, 16).tolist()   # exactly 2 pages
    engine = _engine(cfg, params, prefix_cache=True)
    m = engine.run([(0, prompt, 8), (3, prompt, 8)])
    assert m.prefix_hits == 1 and m.prefix_cow_forks >= 1
    # the full-prompt hit reuses all but the final token
    assert m.prefix_hit_tokens == len(prompt) - 1
    ref = _solo(cfg, params, prompt, 8)
    for r in m.finished:
        assert r.output_tokens == ref
    engine.store.manager.check_invariants()
