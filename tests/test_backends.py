"""The GEMM backend registry: every backend is the same integer arithmetic.

Covers the tentpole refactor's guarantees without optional deps:

* every registered backend == ``direct_matmul`` bit-exactly, over odd /
  non-tile-multiple shapes (exercising the padded-slice path of the fused
  ``spoga_gemm_dequant`` kernel through ``pallas_interpret`` on CPU);
* ``slice_planes`` round-trips for all (n_slices, slice_bits) combos
  including the extremes (-128, int16 min);
* the ``w4a8`` / ``w4a4`` / ``w16a16`` parametric modes run end-to-end and
  ``w4a8`` is bit-exact against a hand-built jnp reference;
* ``models.layers.linear`` routes through the registry (no local dispatch).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.backends import (
    QuantSpec,
    dynamic_quant,
    get_backend,
    list_backends,
    parse_quant_mode,
    quantized_linear,
    resolve_backend,
    set_default_backend,
)
from repro.core.slicing import reconstruct_planes, slice_planes
from repro.core.spoga import direct_matmul, sliced_matmul

EXPECTED_BACKENDS = {
    "jnp_spoga", "jnp_deas", "direct",
    "pallas_spoga", "pallas_spoga_dequant", "pallas_deas", "pallas_interpret",
}

# Odd / non-tile-multiple shapes: every padding path in the kernels fires.
SHAPES = [(8, 16, 8), (33, 70, 45), (1, 249, 16), (130, 257, 100)]


def _rand_int8(seed, shape):
    return jax.random.randint(jax.random.PRNGKey(seed), shape, -128, 128,
                              dtype=jnp.int8)


# ---------------------------------------------------------------------------
# Registry surface
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_builtin_backends_registered(self):
        assert EXPECTED_BACKENDS <= set(list_backends())

    def test_get_backend_unknown_raises(self):
        with pytest.raises(KeyError, match="unknown GEMM backend"):
            get_backend("definitely_not_a_backend")

    def test_resolve_auto_is_jnp_off_tpu(self):
        if jax.default_backend() == "tpu":
            pytest.skip("auto-selection picks the Pallas kernels on TPU")
        b, spec = resolve_backend("int8_spoga")
        assert b.name == "jnp_spoga"
        assert (spec.n_a_slices, spec.n_w_slices, spec.slice_bits) == (2, 2, 4)

    def test_resolve_respects_override_and_default(self):
        b, _ = resolve_backend("int8_spoga", "pallas_interpret")
        assert b.name == "pallas_interpret"
        set_default_backend("direct")
        try:
            b, _ = resolve_backend("int8_spoga")
            assert b.name == "direct"
        finally:
            set_default_backend(None)

    def test_unsupported_spec_rejected(self):
        # The Pallas DEAS baseline is pinned to the paper's W8A8 2x4b spec.
        with pytest.raises(ValueError, match="does not support"):
            resolve_backend("w4a8", "pallas_deas")

    def test_parse_quant_mode(self):
        spec, family = parse_quant_mode("w4a8")
        assert (spec.w_bits, spec.a_bits, family) == (4, 8, "spoga")
        assert (spec.n_w_slices, spec.n_a_slices) == (1, 2)
        spec, _ = parse_quant_mode("w8a8_s2")
        assert (spec.slice_bits, spec.n_a_slices) == (2, 4)
        with pytest.raises(ValueError):
            parse_quant_mode("bf16")
        with pytest.raises(ValueError):
            parse_quant_mode("int7_nonsense")


# ---------------------------------------------------------------------------
# Exactness: every backend vs the native direct GEMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(EXPECTED_BACKENDS))
@pytest.mark.parametrize("m,k,n", SHAPES)
def test_backend_exact_vs_direct(name, m, k, n):
    x, w = _rand_int8(m * k + n, (m, k)), _rand_int8(k * n + m, (k, n))
    b, spec = resolve_backend("int8_spoga", name)
    got = b.gemm(x, w, spec)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(direct_matmul(x, w)))


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_fused_dequant_padded_slice_path(m, k, n):
    """pallas_interpret's gemm_dequant (the fused TPU kernel body) on CPU,
    over shapes that force the zero-padding path, vs the jnp epilogue."""
    rng = np.random.default_rng(m + k + n)
    x, w = _rand_int8(m + 1, (m, k)), _rand_int8(n + 2, (k, n))
    xs = jnp.asarray(rng.uniform(1e-3, 0.1, (m, 1)).astype(np.float32))
    ws = jnp.asarray(rng.uniform(1e-3, 0.1, (1, n)).astype(np.float32))
    b, spec = resolve_backend("int8_spoga", "pallas_interpret")
    got = b.gemm_dequant(x, w, xs, ws, spec)
    want = direct_matmul(x, w).astype(jnp.float32) * xs * ws
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("mode", ["w4a8", "w4a4", "w16a16", "w8a8_s2"])
@pytest.mark.parametrize("backend", ["jnp_spoga", "jnp_deas", "pallas_interpret"])
def test_parametric_modes_bitexact_across_backends(mode, backend):
    """All backends produce IDENTICAL integers for every parametric spec
    (int32 accumulation wraps identically everywhere, so this holds even
    for w16a16's mod-2^32 regime)."""
    spec, _ = parse_quant_mode(mode)
    if backend == "pallas_interpret" and spec.slice_bits > 7:
        pytest.skip("Pallas planes ride the MXU byte path")
    rng = np.random.default_rng(hash(mode) % 2**32)
    qa = int(spec.a_qmax)
    qw = int(spec.w_qmax)
    x = jnp.asarray(rng.integers(-qa, qa + 1, (19, 37)), spec.a_dtype)
    w = jnp.asarray(rng.integers(-qw, qw + 1, (37, 11)), spec.w_dtype)
    b, spec = resolve_backend(mode, backend)
    got = b.gemm(x, w, spec)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(direct_matmul(x, w)))


# ---------------------------------------------------------------------------
# slice_planes: exact round-trip for every plane plan
# ---------------------------------------------------------------------------

class TestSlicePlanes:
    CASES = [
        # (dtype, n_slices, slice_bits)
        (jnp.int8, 2, 4),    # the paper's MSN/LSN
        (jnp.int8, 4, 2),    # SCONNA-style narrow slices
        (jnp.int8, 8, 1),    # bit-serial extreme
        (jnp.int8, 1, 8),    # degenerate single plane
        (jnp.int16, 4, 4),   # int16 on nibble hardware
        (jnp.int16, 2, 8),   # int16 on byte hardware
        (jnp.int16, 8, 2),
    ]

    @pytest.mark.parametrize("dtype,n,b", CASES)
    def test_roundtrip_exhaustive_or_extreme(self, dtype, n, b):
        if dtype == jnp.int8:
            x = jnp.arange(-128, 128, dtype=jnp.int8)  # all of int8
        else:
            vals = np.r_[np.array([-32768, -32767, -1, 0, 1, 32766, 32767]),
                         np.random.default_rng(0).integers(-32768, 32768, 512)]
            x = jnp.asarray(vals, jnp.int16)
        planes = slice_planes(x, n, b)
        assert len(planes) == n
        np.testing.assert_array_equal(
            np.asarray(reconstruct_planes(planes, b, dtype)), np.asarray(x))

    def test_plane_ranges(self):
        x = jnp.arange(-128, 128, dtype=jnp.int8)
        lo, hi = slice_planes(x, 2, 4)
        assert int(lo.min()) >= 0 and int(lo.max()) <= 15      # unsigned digit
        assert int(hi.min()) >= -8 and int(hi.max()) <= 7      # signed top

    def test_int4_passthrough(self):
        """1-plane slicing of int4-in-int8 is the identity."""
        x = jnp.arange(-8, 8, dtype=jnp.int8)
        (plane,) = slice_planes(x, 1, 4)
        np.testing.assert_array_equal(np.asarray(plane), np.asarray(x))

    def test_rejects_bad_args(self):
        with pytest.raises(TypeError):
            slice_planes(jnp.zeros((4,), jnp.float32), 2, 4)
        with pytest.raises(ValueError):
            slice_planes(jnp.zeros((4,), jnp.int8), 0, 4)

    @pytest.mark.parametrize("nx,nw,b", [(2, 2, 4), (4, 4, 2), (2, 1, 4), (1, 2, 4)])
    def test_sliced_matmul_matches_direct(self, nx, nw, b):
        x, w = _rand_int8(nx * 10 + nw, (23, 31)), _rand_int8(b, (31, 17))
        got = sliced_matmul(x, w, n_x_slices=nx, n_w_slices=nw, slice_bits=b)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(direct_matmul(x, w)))


# ---------------------------------------------------------------------------
# The quantized-linear pipeline + model hot path
# ---------------------------------------------------------------------------

class TestQuantizedLinearPipeline:
    def _data(self, lead=(6,), k=64, n=32, seed=3):
        kx, kw = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (*lead, k), jnp.float32)
        w = jax.random.normal(kw, (k, n), jnp.float32) * 0.1
        return x, w

    def test_w4a8_bitexact_vs_jnp_reference(self):
        """The acceptance bar: w4a8 through the registry == a hand-built
        quantize/slice/accumulate/dequant reference, bit for bit."""
        x, w = self._data()
        for backend in ("jnp_spoga", "pallas_interpret"):
            got = quantized_linear(x, w, "w4a8", backend=backend,
                                   out_dtype=jnp.float32)
            # reference: int8 row-quant acts, int4 col-quant weights,
            # 2x1-plane radix GEMM, f32 epilogue — all in plain jnp.
            xq, xs = dynamic_quant(x, axis=-1, bits=8)
            wq, ws = dynamic_quant(w, axis=0, bits=4)
            acc = sliced_matmul(xq, wq, n_x_slices=2, n_w_slices=1, slice_bits=4)
            want = acc.astype(jnp.float32) * xs * ws
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("mode,tol", [
        ("int8_spoga", 0.02), ("w4a8", 0.2), ("w4a4", 0.3), ("w8a8_s2", 0.02),
    ])
    def test_pipeline_accuracy(self, mode, tol):
        x, w = self._data(lead=(4, 8))
        y = quantized_linear(x, w, mode, out_dtype=jnp.float32)
        exact = jnp.einsum("...k,kn->...n", x, w)
        rel = float(jnp.linalg.norm(y - exact) / jnp.linalg.norm(exact))
        assert rel < tol, f"{mode}: rel err {rel}"

    def test_w16a16_narrow_k_accuracy(self):
        """int16 operands stay inside the int32 accumulator for narrow K."""
        x, w = self._data(lead=(5,), k=8, n=6)
        y = quantized_linear(x * 1e-2, w, "w16a16", out_dtype=jnp.float32)
        exact = (x * 1e-2) @ w
        rel = float(jnp.linalg.norm(y - exact) / jnp.linalg.norm(exact))
        assert rel < 1e-3, rel  # 16-bit quantization error only

    def test_linear_routes_through_registry(self):
        """models.layers.linear defers to the registry for every quant mode
        (monkeypatch-free check: an explicit backend choice changes nothing
        numerically but must be accepted end-to-end, incl. the Pallas
        interpreter on CPU)."""
        from repro.models.layers import linear

        x, w = self._data(lead=(2, 5))
        y_auto = linear(x, w, "int8_spoga")
        y_interp = linear(x, w, "int8_spoga", "pallas_interpret")
        np.testing.assert_allclose(np.asarray(y_auto, dtype=np.float32),
                                   np.asarray(y_interp, dtype=np.float32),
                                   rtol=2e-2, atol=1e-6)
        y4 = linear(x, w, "w4a8")
        assert y4.shape == y_auto.shape

    def test_linear_no_string_dict_dispatch_in_source(self):
        """Regression guard for the refactor's core claim: the model layer
        carries no mode-string dict dispatch anymore."""
        import inspect

        import repro.models.layers as layers

        src = inspect.getsource(layers)
        assert "int8_spoga\":" not in src and "'int8_spoga':" not in src
        assert "quantized_linear" in src  # routes through the pipeline

    def test_ste_gradients_flow(self):
        x, w = self._data(lead=(7,))
        from repro.models.layers import linear

        def loss(w_):
            return jnp.sum(linear(x, w_, "w4a8", "pallas_interpret") ** 2)

        g = jax.grad(loss)(w)
        assert g.shape == w.shape
        assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))

    def test_moe_grouped_matmul_modes(self):
        """The grouped expert GEMM follows the same spec/family routing."""
        from repro.models.moe import _grouped_matmul

        kx, kw = jax.random.split(jax.random.PRNGKey(11))
        x = jax.random.normal(kx, (3, 4, 16), jnp.float32)   # (E, C, K)
        w = jax.random.normal(kw, (3, 16, 8), jnp.float32) * 0.1
        outs = {m: _grouped_matmul(x, w, m)
                for m in ("int8_spoga", "int8_deas", "int8_direct", "w4a8")}
        for m, o in outs.items():
            assert o.shape == (3, 4, 8), m
        # the three int8 dataflows agree bit-exactly
        np.testing.assert_array_equal(np.asarray(outs["int8_spoga"]),
                                      np.asarray(outs["int8_deas"]))
        np.testing.assert_array_equal(np.asarray(outs["int8_spoga"]),
                                      np.asarray(outs["int8_direct"]))


class TestConfigIntegration:
    def test_config_accepts_parametric_mode_and_backend(self):
        from repro.configs.base import ModelConfig

        cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=8,
                          n_heads=2, n_kv_heads=2, d_ff=16, vocab_size=32,
                          quant_mode="w4a8", gemm_backend="pallas_interpret")
        assert cfg.quant_mode == "w4a8"
        with pytest.raises(ValueError):
            ModelConfig(name="t", family="dense", n_layers=1, d_model=8,
                        n_heads=2, n_kv_heads=2, d_ff=16, vocab_size=32,
                        quant_mode="nope")
        with pytest.raises(KeyError):
            ModelConfig(name="t", family="dense", n_layers=1, d_model=8,
                        n_heads=2, n_kv_heads=2, d_ff=16, vocab_size=32,
                        gemm_backend="nope")

    def test_quant_spec_validation(self):
        with pytest.raises(ValueError):
            QuantSpec(a_bits=1)
        with pytest.raises(ValueError):
            QuantSpec(slice_bits=9)
