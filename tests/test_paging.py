"""Paged KV-cache subsystem: pool bookkeeping invariants, page scatter,
defrag, and the Pallas paged-attention kernel vs its jnp twin.

Engine-level paged-vs-slot output equivalence lives in test_serving.py;
this file covers the subsystem's own contracts: pages are allocated
lowest-first and exactly once, reservations make mid-decode exhaustion
impossible, freed pages return to the pool the same call, defrag moves
pool rows and tables consistently, and the trash page is never handed out.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.kernels.paged_attention import paged_attention, paged_attention_ref
from repro.models import init_params, prefill
from repro.paging import PageManager, PagedCache


# ---------------------------------------------------------------------------
# PageManager (host bookkeeping)
# ---------------------------------------------------------------------------

def test_manager_alloc_free_reuse():
    mgr = PageManager(n_pages=8, page_size=4, n_lanes=3, max_pages_per_lane=4)
    assert mgr.free_pages == 7  # page 0 reserved (trash)
    mgr.admit(0, reserve_tokens=12)           # 3 pages promised
    assert mgr.available == 4
    got = mgr.alloc(0, 2)
    assert got == [1, 2]                      # lowest-first, deterministic
    assert mgr.block_tables[0, :2].tolist() == [1, 2]
    assert mgr.pages_in_use == 2 and mgr.outstanding == 1
    # growth within reservation
    assert mgr.ensure(0, tokens=9) == [3]     # 9 rows -> 3 pages
    assert mgr.ensure(0, tokens=9) == []      # idempotent
    # a second lane shares the pool
    mgr.admit(1, reserve_tokens=8)
    assert mgr.alloc(1, 2) == [4, 5]
    # free returns pages the same call; tables point at the trash page
    assert mgr.free_lane(0) == 3
    assert mgr.block_tables[0].tolist() == [0, 0, 0, 0]
    assert mgr.free_pages == 5 and mgr.lengths[0] == 0
    # freed ids are reused lowest-first
    mgr.admit(2, reserve_tokens=4)
    assert mgr.alloc(2, 1) == [1]


def test_manager_reservations_guard_exhaustion():
    mgr = PageManager(n_pages=6, page_size=4, n_lanes=4, max_pages_per_lane=4)
    mgr.admit(0, reserve_tokens=12)           # 3 of 5 pages promised
    assert mgr.can_admit(8) and not mgr.can_admit(12)
    with pytest.raises(RuntimeError, match="overcommit"):
        mgr.admit(1, reserve_tokens=16)
    mgr.admit(1, reserve_tokens=8)
    assert mgr.available == 0
    # materializing stays within the promises even at zero availability
    assert mgr.alloc(0, 3) and mgr.alloc(1, 2)
    with pytest.raises(RuntimeError, match="exhausted"):
        mgr.alloc(1, 1)
    with pytest.raises(ValueError, match="pages"):
        mgr.admit(2, reserve_tokens=100)      # wider than a block table
    with pytest.raises(RuntimeError, match="already holds"):
        mgr.admit(0, reserve_tokens=4)


def test_manager_defrag_compacts():
    mgr = PageManager(n_pages=10, page_size=4, n_lanes=3, max_pages_per_lane=3)
    for lane in range(3):
        mgr.admit(lane, reserve_tokens=12)
        mgr.alloc(lane, 3)
    mgr.free_lane(1)                          # pages {4,5,6} go free
    moves = mgr.defrag()
    # lane 2's pages {7,8,9} compact into the freed low ids
    assert sorted(m[0] for m in moves) == [7, 8, 9]
    assert sorted(m[1] for m in moves) == [4, 5, 6]
    used = {p for pages in mgr.lane_pages for p in pages}
    assert used == set(range(1, 7)) and mgr.defrag() == []
    # tables track the remap
    assert mgr.block_tables[2, :3].tolist() == mgr.lane_pages[2]


# ---------------------------------------------------------------------------
# PagedCache (device pools)
# ---------------------------------------------------------------------------

def _single_prefill(cfg, params, n_tokens, cache_len, seed=1):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(seed), (1, n_tokens),
                                          0, cfg.vocab_size)}
    _, single = prefill(params, cfg, batch, cache_len=cache_len)
    return single


def test_paged_cache_insert_roundtrip():
    cfg = reduced(get_config("llama3.2-1b")).with_(remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    pool = PagedCache(cfg, n_lanes=3, cache_len=32, page_size=8)
    mgr = pool.manager

    single = _single_prefill(cfg, params, n_tokens=8, cache_len=16)
    mgr.admit(1, reserve_tokens=16)
    page_ids = mgr.alloc(1, 2)                # 16 rows = 2 pages
    mgr.set_length(1, 8)
    pool.insert(single, 1, page_ids, new_len=8)

    assert pool.pos.tolist() == [0, 8, 0]
    tables = np.asarray(pool.cache["block_tables"])
    assert tables[1, :2].tolist() == page_ids
    # the lane's pages hold exactly the contiguous prefill rows ...
    kp = np.asarray(pool.cache["blocks"][0]["kp"])      # (periods, n_pages, ps, H, D)
    k_one = np.asarray(single["blocks"][0]["k"][:, 0])  # (periods, 16, H, D)
    gathered = kp[:, page_ids].reshape(k_one.shape)
    np.testing.assert_array_equal(gathered, k_one)
    # ... and unallocated pages (incl. the trash page) stay zero
    untouched = [p for p in range(pool.n_pages) if p not in page_ids]
    assert not kp[:, untouched].any()


def test_paged_cache_defrag_preserves_lane_contents():
    cfg = reduced(get_config("llama3.2-1b")).with_(remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    pool = PagedCache(cfg, n_lanes=3, cache_len=32, page_size=8)
    mgr = pool.manager
    for lane, n in ((0, 16), (1, 16), (2, 16)):
        single = _single_prefill(cfg, params, n, cache_len=16, seed=lane + 1)
        mgr.admit(lane, reserve_tokens=16)
        ids = mgr.alloc(lane, 2)
        mgr.set_length(lane, n)
        pool.insert(single, lane, ids, new_len=n)

    def lane_rows(lane):
        kp = np.asarray(pool.cache["blocks"][0]["kp"])
        tbl = np.asarray(pool.cache["block_tables"])[lane, :2]
        return kp[:, tbl].copy()

    before = lane_rows(2)
    pool.free(1)
    assert len(pool.defrag()) > 0             # lane 2 compacted downward
    np.testing.assert_array_equal(lane_rows(2), before)
    assert {p for pages in mgr.lane_pages for p in pages} == set(range(1, 5))


# ---------------------------------------------------------------------------
# Pallas paged-attention kernel vs jnp twin
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("int8", [False, True])
def test_paged_attention_kernel_matches_ref(int8):
    rng = np.random.default_rng(0)
    b, hkv, g, d, ps, n_pages, n_tbl = 3, 2, 4, 32, 8, 16, 4
    q = jnp.asarray(rng.normal(size=(b, hkv, g, d)), jnp.float32)
    tables = jnp.asarray(rng.permutation(np.arange(1, n_pages))[:b * n_tbl]
                         .reshape(b, n_tbl), jnp.int32)
    lengths = jnp.asarray([1, 17, 32], jnp.int32)   # partial / multi / full
    if int8:
        kp = jnp.asarray(rng.integers(-127, 128, (n_pages, ps, hkv, d)), jnp.int8)
        vp = jnp.asarray(rng.integers(-127, 128, (n_pages, ps, hkv, d)), jnp.int8)
        scales = dict(
            k_scale=jnp.asarray(rng.uniform(0.005, 0.02, (n_pages, ps, hkv)), jnp.float32),
            v_scale=jnp.asarray(rng.uniform(0.005, 0.02, (n_pages, ps, hkv)), jnp.float32),
        )
    else:
        kp = jnp.asarray(rng.normal(size=(n_pages, ps, hkv, d)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(n_pages, ps, hkv, d)), jnp.float32)
        scales = {}
    ref = paged_attention_ref(q, kp, vp, tables, lengths, **scales)
    out = paged_attention(q, kp, vp, tables, lengths, interpret=True, **scales)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_ignores_stale_pages():
    """Rows past ``lengths`` — stale data in partially-filled pages, trash
    rows, other lanes' leftovers — must not leak into the output."""
    rng = np.random.default_rng(1)
    b, hkv, g, d, ps, n_pages = 1, 1, 2, 16, 4, 8
    q = jnp.asarray(rng.normal(size=(b, hkv, g, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, ps, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, ps, hkv, d)), jnp.float32)
    tables = jnp.asarray([[1, 2]], jnp.int32)
    out5 = paged_attention(q, kp, vp, tables, jnp.asarray([5]), interpret=True)
    # poison everything past row 5
    kp2 = kp.at[1, 1:].set(99.0).at[2].set(-99.0)
    vp2 = vp.at[1, 1:].set(99.0).at[2].set(-99.0)
    out5b = paged_attention(q, kp2, vp2, tables, jnp.asarray([5]), interpret=True)
    # row 5 = page 1, offset 1 -> that row matters, rows 6+ don't
    kp3 = kp.at[2, 2:].set(99.0)
    vp3 = vp.at[2, 2:].set(99.0)
    out6 = paged_attention(q, kp3, vp3, tables, jnp.asarray([6]), interpret=True)
    ref6 = paged_attention(q, kp, vp, tables, jnp.asarray([6]), interpret=True)
    assert not np.allclose(np.asarray(out5), np.asarray(out5b))  # valid row changed
    np.testing.assert_allclose(np.asarray(out6), np.asarray(ref6))
